"""Continuous-batching serving throughput under a Poisson arrival trace.

Replays one open-loop trace (exponential inter-arrival times in engine
ticks, mixed generation lengths) against the ServingEngine at several slot
counts and reports, per slot count:

  tok_per_s       generated tokens / wall-clock of the whole trace
  p50_ms / p95_ms request latency (arrival -> final token), wall-clock
  steps           engine ticks to drain the trace

Compilation is excluded: each slot count warms up prefill + its pool-width
decode step on a throwaway request before the timed run. Prompts share one
length so prefill compiles once (the engine docstring covers bucketing).

With --out the per-slot-count rows are also written as machine-readable
JSON (``BENCH_serve_throughput.json``) for CI artifact tracking; wall-clock
numbers are host-dependent, so CI archives them instead of gating on them.

  PYTHONPATH=src python -m benchmarks.serve_throughput --smoke
  PYTHONPATH=src python benchmarks/serve_throughput.py --smoke --slots 1,4,8
"""
from __future__ import annotations

import argparse
import json
import time

import numpy as np


def build_trace(rng, num_requests: int, prompt_len: int, gen: int,
                rate: float, vocab: int):
    """Open-loop Poisson trace: arrival tick, prompt, gen length per request."""
    gaps = rng.exponential(1.0 / rate, size=num_requests)
    arrivals = np.floor(np.cumsum(gaps)).astype(int)
    arrivals[0] = 0
    prompts = [rng.integers(0, vocab, size=prompt_len, dtype=np.int32)
               for _ in range(num_requests)]
    gens = rng.integers(max(1, gen // 2), gen + 1, size=num_requests)
    return arrivals, prompts, gens


def run_trace(params, cfg, *, num_slots: int, max_tokens: int,
              arrivals, prompts, gens) -> dict:
    from repro.serving import ServingEngine

    # warmup: compile prefill + this pool width's decode step off the clock
    warm = ServingEngine(params, cfg, num_slots=num_slots,
                         max_tokens=max_tokens)
    warm.submit(prompts[0], 2)
    warm.run()

    eng = ServingEngine(params, cfg, num_slots=num_slots,
                        max_tokens=max_tokens)
    ids = [eng.submit(p, int(g), arrival_step=int(a))
           for p, g, a in zip(prompts, gens, arrivals)]
    t0 = time.monotonic()
    fin = eng.run()
    dt = time.monotonic() - t0

    lats = np.array([fin[i].latency_s for i in ids])
    toks = sum(len(fin[i].tokens) for i in ids)
    return {
        "slots": num_slots,
        "tok_per_s": toks / dt,
        "p50_ms": float(np.percentile(lats, 50) * 1e3),
        "p95_ms": float(np.percentile(lats, 95) * 1e3),
        "steps": eng.step_count,
        "wall_s": dt,
        "tokens": toks,
    }


def run(arch: str = "llama_moe_4_16", smoke: bool = True,
        slot_counts=(1, 4, 8), num_requests: int = 8, prompt_len: int = 16,
        gen: int = 8, rate: float = 0.5, seed: int = 0,
        out: str = "") -> list[dict]:
    import jax

    from repro.configs.registry import get_config
    from repro.models.model import model_init

    cfg = get_config(arch, smoke=smoke)
    params = model_init(jax.random.PRNGKey(seed), cfg)
    rng = np.random.default_rng(seed)
    arrivals, prompts, gens = build_trace(
        rng, num_requests, prompt_len, gen, rate, cfg.vocab_size)
    max_tokens = prompt_len + gen + 1

    rows = []
    for s in slot_counts:
        rows.append(run_trace(params, cfg, num_slots=s, max_tokens=max_tokens,
                              arrivals=arrivals, prompts=prompts, gens=gens))
    if out:
        with open(out, "w") as f:
            json.dump({
                "host_backend": jax.default_backend(),
                "config": {"arch": arch, "smoke": smoke,
                           "requests": num_requests, "prompt_len": prompt_len,
                           "gen": gen, "rate": rate, "seed": seed},
                "rows": rows,
            }, f, indent=2)
    return rows


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama_moe_4_16")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--slots", default="1,4,8",
                    help="comma-separated slot counts")
    ap.add_argument("--requests", type=int, default=0,
                    help="0 -> 8 for --smoke, 32 otherwise")
    ap.add_argument("--prompt", type=int, default=0,
                    help="0 -> 16 for --smoke, 64 otherwise")
    ap.add_argument("--gen", type=int, default=0,
                    help="0 -> 8 for --smoke, 32 otherwise")
    ap.add_argument("--rate", type=float, default=0.5,
                    help="mean arrivals per engine tick")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--out", default="",
                    help="also write the rows as JSON to this path")
    args = ap.parse_args()

    slot_counts = [int(s) for s in args.slots.split(",")]
    n = args.requests or (8 if args.smoke else 32)
    p = args.prompt or (16 if args.smoke else 64)
    g = args.gen or (8 if args.smoke else 32)

    rows = run(args.arch, smoke=args.smoke, slot_counts=slot_counts,
               num_requests=n, prompt_len=p, gen=g, rate=args.rate,
               seed=args.seed, out=args.out)
    print(f"# serve_throughput arch={args.arch} smoke={args.smoke} "
          f"requests={n} prompt={p} gen<={g} rate={args.rate}")
    print("slots,tok_per_s,p50_ms,p95_ms,steps,wall_s,tokens")
    for r in rows:
        print(f"{r['slots']},{r['tok_per_s']:.1f},{r['p50_ms']:.0f},"
              f"{r['p95_ms']:.0f},{r['steps']},{r['wall_s']:.2f},{r['tokens']}")


if __name__ == "__main__":
    main()
