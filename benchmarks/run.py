"""Benchmark harness — one entry per paper artifact + the roofline table.

``PYTHONPATH=src python -m benchmarks.run`` prints, per benchmark, a
``name,us_per_call,derived`` CSV row (wall time of the benchmark itself plus
its headline derived metric), then the detailed tables.
"""
from __future__ import annotations

import os
import sys
import time


def _timed(name, fn):
    t0 = time.perf_counter()
    out = fn()
    us = (time.perf_counter() - t0) * 1e6
    return name, us, out


def main() -> None:
    # Force multi-device CPU BEFORE the first jax import so moe_path's
    # sharded-forward row is emitted from this entry point too — otherwise
    # this harness silently overwrites BENCH_moe_path.json with a skipped
    # row on single-device hosts.
    if "jax" not in sys.modules:
        os.environ["XLA_FLAGS"] = (
            os.environ.get("XLA_FLAGS", "")
            + " --xla_force_host_platform_device_count=4").strip()

    from benchmarks import paper_fig4, paper_fig5, paper_table1, roofline

    rows = []

    name, us, fig4 = _timed("paper_fig4_cache", paper_fig4.run)
    rows.append((name, us,
                 f"kvgo_lat_x8={fig4['claims']['lat_x_vs_none@8']:.2f}"))

    name, us, fig5 = _timed("paper_fig5_sched", paper_fig5.run)
    best = max(v["eff_x"] for k, v in fig5.items() if k != "baseline")
    rows.append((name, us, f"best_area_eff_x={best:.2f}"))

    name, us, t1 = _timed("paper_table1_total", paper_table1.run)
    rows.append((name, us,
                 f"s4o_density={t1['S4O+KVGO']['density']:.1f}GOPS/W/mm2"))

    def _roof():
        return roofline.load_all()
    name, us, roof = _timed("roofline_table", _roof)
    if roof:
        worst = min(r["roofline"]["mfu_upper_bound"] for r in roof)
        rows.append((name, us, f"cells={len(roof)},min_mfu_bound={worst:.3f}"))
    else:
        rows.append((name, us, "cells=0 (run repro.launch.dryrun first)"))

    # kernel micro-benchmarks (interpret mode on CPU: correctness-path timing)
    def _kern():
        import jax, jax.numpy as jnp
        from repro.kernels.ops import moe_ffn_pallas
        from repro.core.routing import token_choice
        key = jax.random.PRNGKey(0)
        T, d, f, E, k = 256, 256, 512, 8, 2
        x = jax.random.normal(key, (T, d), jnp.float32) * 0.1
        bank = {"wg": jax.random.normal(key, (E, d, f)) * 0.05,
                "wi": jax.random.normal(key, (E, d, f)) * 0.05,
                "wo": jax.random.normal(key, (E, f, d)) * 0.05}
        gate = jax.random.normal(key, (d, E)) * 0.1
        r = token_choice(x, gate, k)
        y = moe_ffn_pallas(x, r.expert_idx, r.weights, bank, E, bn=64)
        y.block_until_ready()
        t0 = time.perf_counter()
        for _ in range(3):
            moe_ffn_pallas(x, r.expert_idx, r.weights, bank, E,
                           bn=64).block_until_ready()
        return (time.perf_counter() - t0) / 3 * 1e6
    name, us, per_call = _timed("kernel_moe_gmm_interpret", _kern)
    rows.append((name, us, f"us_per_call={per_call:.0f}"))

    # MoE execution-path trajectory: xla-masked vs pallas, dense vs selected
    # decode. Writes a FRESH report (gitignored) — never the committed
    # BENCH_moe_path.json baseline that check_regression.py gates CI against
    from benchmarks import moe_path
    name, us, mp = _timed(
        "moe_path",
        lambda: moe_path.run(smoke=True, out="BENCH_moe_path.fresh.json"))
    rows.append((name, us,
                 f"fwd_flop_ratio_xla={mp['forward']['redundant_flop_ratio_xla']:.2f}"
                 f"/pallas={mp['forward']['redundant_flop_ratio_pallas']:.2f},"
                 f"decode_row_x={mp['decode']['row_ratio_dense_over_selected']:.1f}"))

    # continuous-batching throughput on a Poisson trace (refreshes the
    # committed BENCH_serve_throughput.json; the deterministic
    # paged-occupancy rows are CI-gated, the wall-clock rows archived only)
    from benchmarks import serve_throughput
    name, us, st = _timed(
        "serve_throughput",
        lambda: serve_throughput.run(smoke=True, slot_counts=(1, 4),
                                     out="BENCH_serve_throughput.json"))
    best = max(st["rows"], key=lambda r: r["tok_per_s"])
    pd = st["paged_vs_dense"]
    rows.append((name, us,
                 f"best_tok_per_s={best['tok_per_s']:.1f}@"
                 f"{best['slots']}slots,p95_ms={best['p95_ms']:.0f},"
                 f"paged_streams={pd['paged']['max_concurrent']}"
                 f"v{pd['dense']['max_concurrent']}"))

    print("name,us_per_call,derived")
    for n, u, d in rows:
        print(f"{n},{u:.0f},{d}")
    print()

    paper_fig4.main()
    print()
    paper_fig5.main()
    print()
    paper_table1.main()
    print()
    roofline.main()


if __name__ == "__main__":
    main()
