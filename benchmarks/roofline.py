"""Roofline analysis (deliverable g) — reads the dry-run artifacts and derives
the three per-device roofline terms for every (arch x shape x mesh) cell:

  compute_s    = HLO_FLOPs_per_device / 197 TFLOP/s (bf16, TPU v5e)
  memory_s     = HLO_bytes_per_device / 819 GB/s HBM
  collective_s = collective_bytes_per_device / 50 GB/s ICI link

FLOPs/bytes/collective-bytes are the loop-aware totals (launch/hlo_cost.py:
while bodies x known_trip_count — XLA's raw cost_analysis counts loop bodies
once). MODEL_FLOPS is the analytic useful compute (6·N_active·D for training,
2·N_active per decoded token), so MODEL_FLOPS / HLO_FLOPs exposes
remat/replication waste.
"""
from __future__ import annotations

import glob
import json
import os

from repro.configs.base import SHAPES
from repro.configs.registry import get_config

PEAK_FLOPS = 197e12          # bf16 / chip
HBM_BW = 819e9               # B/s
ICI_BW = 50e9                # B/s per link

ART_DIR = os.path.join(os.path.dirname(__file__), "..", "artifacts", "dryrun")


def model_flops_per_device(rec: dict) -> float:
    cfg = get_config(rec["arch"])
    shape = SHAPES[rec["shape"]]
    n_act = cfg.active_param_count()
    n_dev = rec["devices"]
    if shape.kind == "train":
        tokens = shape.seq_len * shape.global_batch
        return 6.0 * n_act * tokens / n_dev
    if shape.kind == "prefill":
        tokens = shape.seq_len * shape.global_batch
        return 2.0 * n_act * tokens / n_dev
    # decode: one token per sequence
    return 2.0 * n_act * shape.global_batch / n_dev


def analyze_record(rec: dict) -> dict:
    comp = rec["flops_per_device"] / PEAK_FLOPS
    mem = rec["bytes_per_device"] / HBM_BW
    coll = rec["collective_bytes_per_device"]["total"] / ICI_BW
    terms = {"compute_s": comp, "memory_s": mem, "collective_s": coll}
    dominant = max(terms, key=terms.get)
    mf = model_flops_per_device(rec)
    bound = max(terms.values())
    return {
        **{k: round(v, 6) for k, v in terms.items()},
        "dominant": dominant,
        "step_lower_bound_s": round(bound, 6),
        "model_flops_per_device": mf,
        "useful_ratio": round(mf / max(rec["flops_per_device"], 1), 4),
        "mfu_upper_bound": round(mf / PEAK_FLOPS / max(bound, 1e-12), 4),
        "memory_fit_gib": round(
            (rec["memory"]["argument_bytes"]
             + rec["memory"].get("temp_bytes_tpu_corrected",
                                 rec["memory"]["temp_bytes"])) / 2**30, 2),
    }


ADVICE = {
    "compute_s": "compute-bound: raise MFU via larger per-device batch or "
                 "fused kernels; already near the right regime",
    "memory_s": "HBM-bound: fuse elementwise chains, cut fp32 intermediates, "
                "raise arithmetic intensity (bigger tiles / batch)",
    "collective_s": "ICI-bound: reshard to cut gathers (FSDP->pure-TP or "
                    "vice versa), overlap collectives with compute, or "
                    "shrink the collective payload (bf16 reduce)",
}


def load_all(mesh: str | None = None, include_variants: bool = False) -> list:
    out = []
    for path in sorted(glob.glob(os.path.join(ART_DIR, "*.json"))):
        rec = json.load(open(path))
        if mesh and rec["mesh"] != mesh:
            continue
        if not include_variants and rec.get("variant", "baseline") != "baseline":
            continue
        rec["roofline"] = analyze_record(rec)
        out.append(rec)
    return out


def main():
    rows = load_all()
    if not rows:
        print("no dry-run artifacts found — run repro.launch.dryrun first")
        return
    print(f"{'arch':22s} {'shape':12s} {'mesh':5s} {'compute_s':>10s} "
          f"{'memory_s':>10s} {'collect_s':>10s} {'dominant':>12s} "
          f"{'useful':>7s} {'MFU<=':>6s} {'mem GiB':>8s}")
    for rec in rows:
        r = rec["roofline"]
        print(f"{rec['arch']:22s} {rec['shape']:12s} {rec['mesh']:5s} "
              f"{r['compute_s']:10.4f} {r['memory_s']:10.4f} "
              f"{r['collective_s']:10.4f} {r['dominant'][:-2]:>12s} "
              f"{r['useful_ratio']:7.3f} {r['mfu_upper_bound']:6.3f} "
              f"{r['memory_fit_gib']:8.2f}")
    print("\nbottleneck advice:")
    for k, v in ADVICE.items():
        print(f"  {k[:-2]:>12s}: {v}")


if __name__ == "__main__":
    main()
