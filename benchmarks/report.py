"""Generate the EXPERIMENTS.md §Dry-run and §Roofline tables from the
artifacts produced by repro.launch.dryrun."""
from __future__ import annotations

from benchmarks.roofline import load_all


def dryrun_table(mesh: str) -> str:
    rows = load_all(mesh)
    out = [f"| arch | shape | flops/dev | bytes/dev | coll MiB/dev | "
           f"temp GiB (tpu-corr) | args GiB | compile s |",
           "|---|---|---|---|---|---|---|---|"]
    for r in rows:
        m = r["memory"]
        out.append(
            f"| {r['arch']} | {r['shape']} | {r['flops_per_device']:.3e} "
            f"| {r['bytes_per_device']:.3e} "
            f"| {r['collective_bytes_per_device']['total']/2**20:,.0f} "
            f"| {m['temp_bytes']/2**30:.2f} ({m['temp_bytes_tpu_corrected']/2**30:.2f}) "
            f"| {m['argument_bytes']/2**30:.2f} "
            f"| {r['compile_s']:.0f} |")
    return "\n".join(out)


def roofline_table(mesh: str = "1pod") -> str:
    rows = load_all(mesh)
    out = ["| arch | shape | compute s | memory s | collective s | dominant | "
           "MODEL/HLO flops | MFU bound |",
           "|---|---|---|---|---|---|---|---|"]
    for r in rows:
        rf = r["roofline"]
        out.append(
            f"| {r['arch']} | {r['shape']} | {rf['compute_s']:.4f} "
            f"| {rf['memory_s']:.4f} | {rf['collective_s']:.4f} "
            f"| **{rf['dominant'][:-2]}** | {rf['useful_ratio']:.3f} "
            f"| {rf['mfu_upper_bound']:.3f} |")
    return "\n".join(out)


def main():
    for mesh in ("1pod", "2pod"):
        print(f"### Dry-run — {mesh}\n")
        print(dryrun_table(mesh))
        print()
    print("### Roofline (single-pod)\n")
    print(roofline_table("1pod"))


if __name__ == "__main__":
    main()
