"""MoE execution-path benchmark: xla-masked vs Pallas tile-dispatch, and
GO-cache decode dense-vs-selected — the perf trajectory for the C1/C4 engine.

Measures, on the SAME weights and routing:

  forward   group_forward on backend="xla" (masked member loop: g x redundant
            FLOPs over the pooled group buffer) vs backend="pallas" (each
            routed pair streams through the grouped GEMM exactly once).
            Reports us/call and the redundant-FLOP ratio of each path
            (FFN rows computed / routed pairs; 1.0 = zero redundancy).
  sharded   moe_forward_ep under a ("data", "model") mesh: the per-shard
            [E_loc, C, d] capacity buffers of backend="xla" vs the per-shard
            tile plans of backend="pallas" (each shard plans only its local
            experts; drop parity pinned in tests). Skipped gracefully on
            single-device hosts — `main()` forces host devices via XLA_FLAGS
            before jax imports, so the CLI always emits the row on CPU.
  decode    the GO-cache step with the dense fallback (expert_ffn_all: B*E
            FFN rows per step) vs the selected-experts path
            (kernels/ops.py:go_selected_ffn: the static-capacity decode
            plan with the router's k as occupancy hint). Reports us/step
            (averaged over the tick sequence, budget-fast and fallback
            ticks at their real frequency) and the rows the budgeted plan
            executes. Decode runs at its own serving-shaped width
            (decode_d/decode_d_expert).

Packed-plan occupancy rows: ``grid_tiles_padded`` is the PRE-packing static
grid (one padded run per lane plus the planned drop lane),
``grid_tiles_packed`` the packed plan's static bound (fused lane pairs for
the forward; E x C_fast slots for decode), ``occupied_tiles`` the tiles that
actually carried rows; ``planner_us`` isolates the jitted planner itself.
The bench-regression guard (benchmarks/check_regression.py, run by CI)
gates the deterministic rows — FLOP ratios, grid tiles, executed decode
rows — against the committed copy of this file's output.

Emits machine-readable ``BENCH_moe_path.json`` next to the cwd (or --out)
so CI can track the numbers over time. On CPU the pallas kernels run in
interpret mode — absolute us are a correctness-path baseline there; the
row/FLOP accounting is platform-independent.

Usage:  PYTHONPATH=src python -m benchmarks.moe_path [--smoke] [--out PATH]
                                                     [--sharded-devices N]
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import math
import os
import sys
import time


def _timeit(fn, iters: int = 3) -> float:
    fn()  # compile / warm
    t0 = time.perf_counter()
    for _ in range(iters):
        fn()
    return (time.perf_counter() - t0) / iters * 1e6


def run(smoke: bool = True, out: str = "BENCH_moe_path.json") -> dict:
    import jax
    import jax.numpy as jnp
    import numpy as np

    from repro.configs.base import MoEConfig
    from repro.core import moe as MOE
    from repro.core.go_cache import go_cache_init, go_cache_step
    from repro.core.grouping import default_groups, group_of_expert_from_groups
    from repro.kernels.ops import go_selected_ffn, plan_tile_dispatch

    if smoke:
        T, d, E, k, g, de, bn, steps, B = 128, 64, 8, 2, 2, 64, 8, 8, 16
        d_dec, de_dec = 128, 128     # decode rows at a serving-shaped width
    else:
        T, d, E, k, g, de, bn, steps, B = 1024, 256, 16, 4, 2, 256, 128, 32, 32
        d_dec, de_dec = d, de

    e_xla = MoEConfig(num_experts=E, top_k=k, d_expert=de, group_size=g,
                      capacity_factor=2.0, backend="xla")
    e_pal = dataclasses.replace(e_xla, backend="pallas", gmm_block_rows=bn)
    key = jax.random.PRNGKey(0)
    params = MOE.moe_init(key, d, e_xla, jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (T, d), jnp.float32) * 0.3
    goe = jnp.asarray(group_of_expert_from_groups(default_groups(e_xla)))

    f_xla = jax.jit(lambda x: MOE.group_forward(params, x, e_xla, goe)[0])
    f_pal = jax.jit(lambda x: MOE.group_forward(params, x, e_pal, goe)[0])
    us_xla = _timeit(lambda: f_xla(x).block_until_ready())
    us_pal = _timeit(lambda: f_pal(x).block_until_ready())

    # FFN-row accounting: the xla masked loop runs every group member over
    # the WHOLE pooled group buffer; pallas computes each pair's tile once
    # (a fused pair's straddle tile runs one extra masked pass — counted).
    N = T * k
    G = E // g
    C_exp = max(1, math.ceil(T * k / E * e_xla.capacity_factor))
    C_grp = max(1, math.ceil(g * C_exp * 0.7))
    rows_xla = g * G * C_grp                     # g member passes x G*C_grp
    from repro.core.routing import token_choice
    r = token_choice(x, params["gate"], k)
    ef = r.expert_idx.reshape(-1).astype(jnp.int32)
    # the SAME group-major fused plan group_forward's pallas branch builds
    members = MOE._members_matrix(goe, G, g)
    _, rank_of_expert, fuse = MOE.group_lane_map(members, g)
    plan = plan_tile_dispatch(rank_of_expert[ef], E, bn, fuse=fuse)
    tv = np.asarray(plan.tile_valid)
    straddle = np.asarray(plan.tile_expert2) != np.asarray(plan.tile_expert)
    rows_pal = int(bn * (tv.sum() + (tv & straddle).sum()))
    # static grids: pre-packing worst case (one padded run per lane + the
    # planned drop lane) vs the packed plan's fused bound
    plan_unfused = plan_tile_dispatch(rank_of_expert[ef], E, bn)
    grid_padded_fwd = plan_unfused.n_tiles
    grid_packed_fwd = plan.n_tiles
    # planner cost, isolated: the packed plan alone, jitted
    plan_jit = jax.jit(lambda ef: plan_tile_dispatch(ef, E, bn, fuse=fuse))
    us_plan_fwd = _timeit(
        lambda: jax.block_until_ready(plan_jit(ef)))

    # --- sharded forward: EP shard_map, per-shard buffers vs per-shard plans
    n_dev = jax.device_count()
    M = 2 if (n_dev >= 2 and E % 2 == 0) else 0
    if M:
        mesh = jax.make_mesh((n_dev // M, M), ("data", "model"))
        B_ep = 4
        S = T // B_ep
        h = x.reshape(B_ep, S, d)
        f_ep_x = jax.jit(lambda h: MOE.moe_forward_ep(params, h, e_xla))
        f_ep_p = jax.jit(lambda h: MOE.moe_forward_ep(params, h, e_pal))
        with mesh:
            us_ep_x = _timeit(lambda: f_ep_x(h)[0].block_until_ready())
            us_ep_p = _timeit(lambda: f_ep_p(h)[0].block_until_ready())
            a_x = f_ep_x(h)[1]
            a_p = f_ep_p(h)[1]
        assert int(a_x["dropped"]) == int(a_p["dropped"]), \
            "sharded xla vs pallas drop sets diverged"
        # rows: xla fills every shard's [E_loc, C] buffer (B*E*C total);
        # pallas computes each shard's tile-padded local runs
        C_ep = max(1, math.ceil(S * k / E * e_xla.capacity_factor))
        from repro.core.routing import token_choice as tc
        cnt_ep = np.stack([np.bincount(
            np.asarray(tc(h[b], params["gate"], k).expert_idx).reshape(-1),
            minlength=E) for b in range(B_ep)])
        rows_ep_x = B_ep * E * C_ep
        rows_ep_p = int(((cnt_ep + bn - 1) // bn * bn).sum())
        n_pairs_ep = B_ep * S * k
        sharded = {
            "mesh": {"data": n_dev // M, "model": M},
            "us_xla": round(us_ep_x, 1),
            "us_pallas": round(us_ep_p, 1),
            "routed_pairs": n_pairs_ep,
            "ffn_rows_xla": rows_ep_x,
            "ffn_rows_pallas": rows_ep_p,
            "redundant_flop_ratio_xla": round(rows_ep_x / n_pairs_ep, 3),
            "redundant_flop_ratio_pallas": round(rows_ep_p / n_pairs_ep, 3),
            "dropped": int(a_p["dropped"]),
        }
    else:
        sharded = {"skipped": f"needs >= 2 devices with E % 2 == 0 "
                              f"(have {n_dev} devices, E={E})"}

    # --- GO-cache decode: dense all-experts vs selected-only grouped GEMM
    # (the static-capacity decode plan with the router's top_k as the
    # occupancy hint — what blocks.py wires into the serving engine).
    # Decode gets its own serving-shaped widths (d_dec/de_dec): at the tiny
    # forward-smoke width the step is pure dispatch overhead and the row
    # savings are invisible.
    e_dec = dataclasses.replace(e_xla, d_expert=de_dec)
    params_dec = MOE.moe_init(jax.random.PRNGKey(5), d_dec, e_dec,
                              jnp.float32)
    cache = go_cache_init(B, E, k, d_dec, jnp.float32)
    gate = params_dec["gate"]
    dense_fn = lambda xt: MOE.expert_ffn_all(params_dec, xt)
    sel_fn = lambda xt, sel, gg: go_selected_ffn(
        xt, sel, gg, params_dec["experts"], E, bn=bn, topk_hint=k)[0]

    step_dense = jax.jit(lambda c, xt, t: go_cache_step(
        c, xt, t, gate, dense_fn))
    step_sel = jax.jit(lambda c, xt, t: go_cache_step(
        c, xt, t, gate, contrib_fn=sel_fn))

    # warm the cache so selection is sparse (empty cache selects everything)
    xs = jax.random.normal(jax.random.PRNGKey(2), (steps + k, B, d_dec)) * 0.3
    for t in range(k):
        cache = step_dense(cache, xs[t], t).cache

    # read the budget off the plan go_selected_ffn ACTUALLY builds (the
    # auto-resolved executor decides how bn rounds it)
    g0 = jax.nn.softmax(
        xs[k].astype(jnp.float32) @ gate.astype(jnp.float32), axis=-1)
    sel0 = step_dense(cache, xs[k], k).selected
    _, gplan = go_selected_ffn(xs[k], sel0, g0, params_dec["experts"], E,
                               bn=bn, topk_hint=k)
    C_fast = gplan.C_fast
    sel_pairs = 0
    sel_rows = 0                     # rows the budgeted plan executes
    fallbacks = 0
    c_d, c_s = cache, cache
    for t in range(k, k + steps):
        res = step_dense(c_d, xs[t], t)
        c_d = res.cache
        n_sel = int(res.selected.sum())
        over = int(np.asarray(res.selected).sum(0).max()) > C_fast
        fallbacks += over
        sel_pairs += n_sel
        sel_rows += E * (B if over else C_fast)
    for t in range(k, k + steps):
        c_s = step_sel(c_s, xs[t], t).cache
    assert np.allclose(np.asarray(c_d.outputs), np.asarray(c_s.outputs),
                       atol=1e-5), "dense vs selected decode diverged"

    # per-step time averaged over the WHOLE tick sequence, so budget-fast
    # and fallback ticks weigh in at their real frequency
    def _loop(stepfn):
        def go():
            c = cache
            for t in range(k, k + steps):
                c = stepfn(c, xs[t], t).cache
            jax.block_until_ready(c.outputs)
        return go
    us_dense = _timeit(_loop(step_dense), iters=5) / steps
    us_sel = _timeit(_loop(step_sel), iters=5) / steps

    # decode planner alone: counts + the one top_k that recovers the packed
    # gather (the persistent plan's only per-tick work), jitted
    def _decode_plan(sel):
        selT = sel.T
        ar = jnp.arange(B, dtype=jnp.int32)
        keys_ = jnp.where(selT, B - ar[None, :], -1 - ar[None, :])
        return selT.sum(1), jax.lax.top_k(keys_, C_fast)[1]
    dplan_jit = jax.jit(_decode_plan)
    us_plan_dec = _timeit(lambda: jax.block_until_ready(dplan_jit(sel0)))

    # old pre-packing decode grid: every (token, expert) pair planned as a
    # real row, plus the per-lane padding and the planned drop lane
    grid_padded_dec = math.ceil((B * E + (E + 1) * bn) / bn)
    grid_packed_dec = gplan.n_tiles_fast

    report = {
        "host_backend": jax.default_backend(),
        "config": {"T": T, "d": d, "E": E, "k": k, "g": g, "d_expert": de,
                   "bn": bn, "decode_batch": B, "decode_steps": steps,
                   "decode_d": d_dec, "decode_d_expert": de_dec},
        "forward": {
            "us_xla_masked": round(us_xla, 1),
            "us_pallas": round(us_pal, 1),
            "routed_pairs": N,
            "ffn_rows_xla_masked": rows_xla,
            "ffn_rows_pallas": rows_pal,
            "redundant_flop_ratio_xla": round(rows_xla / N, 3),
            "redundant_flop_ratio_pallas": round(rows_pal / N, 3),
            "grid_tiles_padded": grid_padded_fwd,
            "grid_tiles_packed": grid_packed_fwd,
            "occupied_tiles": int(plan.occupied),
        },
        "forward_sharded": sharded,
        "decode": {
            "us_step_dense": round(us_dense, 1),
            "us_step_selected": round(us_sel, 1),
            "rows_dense_per_steps": steps * B * E,
            "rows_selected_per_steps": sel_rows,
            "selected_pairs_per_steps": sel_pairs,
            "row_ratio_dense_over_selected": round(
                steps * B * E / max(1, sel_rows), 2),
            "grid_tiles_padded": grid_padded_dec,
            "grid_tiles_packed": grid_packed_dec,
            "budget_rows_per_lane": C_fast,
            "budget_fallback_steps": fallbacks,
        },
        "planner_us": {
            "forward_plan": round(us_plan_fwd, 1),
            "decode_plan": round(us_plan_dec, 1),
        },
    }
    if out:
        with open(out, "w") as f:
            json.dump(report, f, indent=2)
    return report


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--out", default="BENCH_moe_path.fresh.json",
                    help="output path; the default is a gitignored FRESH "
                         "report — point it at BENCH_moe_path.json only to "
                         "deliberately re-baseline the CI regression gate")
    ap.add_argument("--sharded-devices", type=int, default=4,
                    help="force this many host devices (XLA_FLAGS, set "
                         "before jax imports) so the sharded-forward row "
                         "runs on single-device CPU hosts; 0 = don't force")
    args = ap.parse_args()
    if args.sharded_devices > 1 and "jax" not in sys.modules:
        os.environ["XLA_FLAGS"] = (
            os.environ.get("XLA_FLAGS", "")
            + f" --xla_force_host_platform_device_count="
              f"{args.sharded_devices}").strip()
    rep = run(smoke=args.smoke, out=args.out)
    f, sh, dck = rep["forward"], rep["forward_sharded"], rep["decode"]
    pl = rep["planner_us"]
    print(f"forward: xla {f['us_xla_masked']:.0f}us "
          f"(FLOP ratio {f['redundant_flop_ratio_xla']:.2f}x) vs "
          f"pallas {f['us_pallas']:.0f}us "
          f"(ratio {f['redundant_flop_ratio_pallas']:.2f}x, grid "
          f"{f['grid_tiles_packed']}/{f['grid_tiles_padded']} tiles, "
          f"{f['occupied_tiles']} occupied)")
    if "skipped" in sh:
        print(f"sharded: skipped — {sh['skipped']}")
    else:
        print(f"sharded: mesh {sh['mesh']} xla {sh['us_xla']:.0f}us "
              f"(ratio {sh['redundant_flop_ratio_xla']:.2f}x) vs "
              f"pallas {sh['us_pallas']:.0f}us "
              f"(ratio {sh['redundant_flop_ratio_pallas']:.2f}x)")
    print(f"decode:  dense {dck['us_step_dense']:.0f}us/"
          f"{dck['rows_dense_per_steps']} rows vs selected "
          f"{dck['us_step_selected']:.0f}us/{dck['rows_selected_per_steps']} "
          f"rows ({dck['row_ratio_dense_over_selected']:.1f}x fewer, grid "
          f"{dck['grid_tiles_packed']}/{dck['grid_tiles_padded']} tiles, "
          f"{dck['budget_fallback_steps']} fallback ticks)")
    print(f"planner: forward {pl['forward_plan']:.0f}us, "
          f"decode {pl['decode_plan']:.0f}us")
    print(f"wrote {args.out}")


if __name__ == "__main__":
    main()
