"""Paper Table I — total latency / energy / performance density.

Columns: baseline (no cache, no schedule), S2O+KVGO, S4O+KVGO. Two anchors
(baseline & S2O totals) calibrate the non-PIM constants; S4O is a genuine
prediction of the simulator.
"""
from __future__ import annotations

from repro.pim.hermes import HERMES
from repro.pim.simulator import BASELINE, S2O_KVGO, S4O_KVGO, simulate

PAPER = {
    "baseline": (2_297_724, 5_393_776, 10.2),
    "S2O+KVGO": (717_752, 1_096_691, 12.3),
    "S4O+KVGO": (743_078, 1_100_548, 15.6),
}


def run(spec=None) -> dict:
    spec = spec or HERMES
    out = {}
    for name, cfg in [("baseline", BASELINE), ("S2O+KVGO", S2O_KVGO),
                      ("S4O+KVGO", S4O_KVGO)]:
        r = simulate(cfg, spec=spec)
        p = PAPER[name]
        out[name] = {
            "latency_ns": r.latency_ns, "energy_nj": r.energy_nj,
            "density": r.density,
            "paper_latency_ns": p[0], "paper_energy_nj": p[1],
            "paper_density": p[2],
            "latency_ratio": r.latency_ns / p[0],
            "energy_ratio": r.energy_nj / p[1],
        }
    return out


def main():
    out = run()
    print("== Table I: total latency / energy / density ==")
    print(f"{'config':10s} {'lat_ns':>12s} {'paper':>12s} {'en_nJ':>12s} "
          f"{'paper':>12s} {'dens':>6s} {'paper':>6s}")
    for name, v in out.items():
        print(f"{name:10s} {v['latency_ns']:12,.0f} {v['paper_latency_ns']:12,} "
              f"{v['energy_nj']:12,.0f} {v['paper_energy_nj']:12,} "
              f"{v['density']:6.1f} {v['paper_density']:6.1f}")
    print("note: baseline & S2O totals are calibration anchors; S4O and all "
          "Fig4/Fig5 numbers are simulator predictions.")


if __name__ == "__main__":
    main()
