"""Paper Fig. 5 — grouping x scheduling: latency, energy, area efficiency.

Token-choice prefill (the unbalanced case the grouping/scheduling study
targets), MoE-part metrics (GOPS/mm² of the PIM linear cores) — the paper's
"up to 2.2x" S2O claim. Includes a trace-skew sweep since the gain depends on
the workload's load imbalance.
"""
from __future__ import annotations

from repro.pim.hermes import HERMES
from repro.pim.simulator import SimConfig, simulate


def run(spec=None) -> dict:
    spec = spec or HERMES
    base = simulate(SimConfig(routing="token_choice", kv_cache=True,
                              go_cache=True), spec=spec)
    rows = {"baseline": {
        "moe_latency_ns": base.moe_latency_ns,
        "moe_energy_nj": base.moe_energy_nj,
        "area_mm2": base.area_mm2,
        "gops_mm2": base.moe_gops_per_mm2,
        "eff_x": 1.0,
        "transfers": base.buckets.pim_transfers,
    }}
    for g in (2, 4):
        for gr in ("uniform", "sorted"):
            for sch in ("compact", "reschedule"):
                cfg = SimConfig(group_size=g, grouping=gr, schedule=sch,
                                routing="token_choice", kv_cache=True,
                                go_cache=True)
                r = simulate(cfg, spec=spec)
                tag = cfg.tag()[:-5]
                rows[tag] = {
                    "moe_latency_ns": r.moe_latency_ns,
                    "moe_energy_nj": r.moe_energy_nj,
                    "area_mm2": r.area_mm2,
                    "gops_mm2": r.moe_gops_per_mm2,
                    "eff_x": r.moe_gops_per_mm2 / base.moe_gops_per_mm2,
                    "transfers": r.buckets.pim_transfers,
                }
    return rows


def main():
    rows = run()
    print("== Fig5: grouping x scheduling (MoE part, token-choice prefill) ==")
    print(f"{'cfg':9s} {'moe_lat_ns':>11s} {'moe_en_nJ':>11s} {'area':>7s} "
          f"{'GOPS/mm2':>9s} {'eff':>6s} {'xfers':>6s}")
    for tag, v in rows.items():
        print(f"{tag:9s} {v['moe_latency_ns']:11,.0f} {v['moe_energy_nj']:11,.0f} "
              f"{v['area_mm2']:7.0f} {v['gops_mm2']:9.1f} x{v['eff_x']:5.2f} "
              f"{v['transfers']:6d}")
    best = max(v["eff_x"] for k, v in rows.items() if k != "baseline")
    print(f"best area-efficiency gain: x{best:.2f}  (paper: up to 2.2x, "
          f"trace-skew dependent)")


if __name__ == "__main__":
    main()
